"""HoardFS: POSIX-façade file handles over the stripe store.

This is the paper's Requirement 4 made literal: an unmodified, path-reading
consumer ``open``s ``/hoard/<dataset>/shard-XXXXXX.bin``, ``read``s bytes and
``close``s — and underneath, every byte resolves through exactly the same
machinery as the iterator backends:

* byte range -> item ids via :class:`~repro.fs.metadata.MetadataService`,
* item ids -> tri-state classification (stripe hit / fill join / remote
  fall-through) via the shared
  :class:`~repro.core.loader.StripeDataPlane`, which books local-NVMe, peer
  and remote flows on the simulated fabric *byte-identically* to
  ``HoardBackend.batch_io``,
* cold chunks fall through to the remote store via the dataset's
  :class:`~repro.core.prefetch.FillTracker` (join-in-flight dedup included),
* sequential handles drive the non-clairvoyant
  :class:`~repro.fs.readahead.Readahead` window.

Open handles take :meth:`CacheManager.acquire` reader pins for their whole
lifetime, so LRU churn can never evict a dataset somebody has a file open
in — the VFS equivalent of the workload engine's per-job pins.

In materialized mode (``StripeStore(root=...)``) reads deliver the real
bytes: ``ReadResult.data`` is populated when the simulated transfer lands.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Sequence

import numpy as np

from ..core.cache import CacheManager, CacheState, DatasetStat
from ..core.calibration import PAPER, WorkloadCalibration
from ..core.loader import StripeDataPlane
from ..core.metrics import JobMetrics
from ..core.prefetch import FillTracker
from ..core.simclock import Event, SimClock
from ..core.tiers import PagePool, buffer_cache_items
from ..core.topology import Node, Topology
from ..core.writeplane import WRITE_BACK, ChunkCodec, WritePlane
from .metadata import ROOT, FileAttr, MetadataService
from .readahead import Readahead


@dataclass
class ReadResult:
    """Outcome of one ``read``/``pread``.

    ``event`` fires when the bytes have crossed the simulated fabric (the
    POSIX call "returns").  ``nbytes`` is the EOF-clamped byte count.  In
    materialized mode ``data`` is filled in when the event fires — never
    before, because an unfilled chunk's bytes do not exist yet.
    """

    event: Event
    nbytes: int
    data: Optional[bytes] = None


@dataclass
class WriteResult:
    """Outcome of one ``write``/``pwrite``/``ftruncate``.

    ``event`` fires when the bytes are buffered on the writer's NVMe (the
    POSIX call "returns") — durability needs a subsequent :meth:`HoardFS.fsync`.
    """

    event: Event
    nbytes: int


@dataclass
class StatFS:
    """Filesystem-wide view returned by :meth:`HoardFS.statfs` (typed).

    Capacity figures aggregate over the live membership view; ``datasets``
    is :meth:`CacheManager.ls` verbatim (a list of
    :class:`~repro.core.cache.DatasetStat`).  :meth:`as_dict` reproduces
    the pre-typed dict shape key-for-key — nested dataset rows included —
    for JSON dumps and older tooling.
    """

    capacity_bytes: float
    used_bytes: float
    # un-fsync'd buffers sit OUTSIDE used_bytes (the committed copy is what
    # node_usage charges), so free_bytes subtracts them — otherwise admission
    # oversubscribes a node whose NVMe holds unflushed writes
    free_bytes: float
    dirty_bytes: float               # unflushed write-back debt (inside used)
    write_buffer_bytes: float
    # live read-serving backlog across member nodes (contention-aware read
    # scheduler): bytes queued on the read disks and NIC-tx
    read_queue_bytes: float
    open_handles: int
    membership_epoch: int
    members: list[int]
    migrating_chunks: int            # elastic rebalancer's in-flight chunks
    # partial caching (ISSUE 7): datasets resident as a chunk subset — the
    # per-dataset rows carry the honest resident_fraction / chunk_heat_mean
    partial_datasets: int
    datasets: list[DatasetStat]
    # live telemetry snapshot (ISSUE 8) when a hub is attached, else None
    telemetry: Optional[dict]

    def as_dict(self) -> dict:
        """Back-compat mapping, key-identical to the pre-typed ``statfs()``."""
        return {
            "capacity_bytes": self.capacity_bytes,
            "used_bytes": self.used_bytes,
            "free_bytes": self.free_bytes,
            "dirty_bytes": self.dirty_bytes,
            "write_buffer_bytes": self.write_buffer_bytes,
            "read_queue_bytes": self.read_queue_bytes,
            "open_handles": self.open_handles,
            "membership_epoch": self.membership_epoch,
            "members": list(self.members),
            "migrating_chunks": self.migrating_chunks,
            "partial_datasets": self.partial_datasets,
            "datasets": [d.as_dict() for d in self.datasets],
            "telemetry": self.telemetry,
        }


@dataclass
class OpenFile:
    fd: int
    attr: FileAttr
    plane: StripeDataPlane
    readahead: Readahead
    pos: int = 0
    writable: bool = False


@dataclass
class _RAStats:
    hits: int = 0            # reads fully served from resident chunks
    blocked: int = 0         # reads that waited on at least one fill
    seeks: int = 0
    sequential_reads: int = 0
    windows_started: int = 0

    def fold(self, ra: Readahead) -> None:
        self.seeks += ra.seeks
        self.sequential_reads += ra.sequential_reads
        self.windows_started += ra.windows_started


class HoardFS:
    """One node's mount of the Hoard namespace (think: a FUSE mount).

    Reads issued through this instance originate at ``node`` — locality,
    peer-stripe traffic and NIC contention are all computed from that
    vantage point, exactly as for an iterator job placed on the node.
    """

    def __init__(
        self,
        clock: SimClock,
        topology: Topology,
        cache: CacheManager,
        meta: MetadataService,
        node: Node,
        *,
        cal: WorkloadCalibration = PAPER,
        mdr: Optional[float] = None,
        metrics: Optional[JobMetrics] = None,
        readahead_window: Optional[int] = 8,
        readahead_inflight: int = 4,
        readahead_min_streak: int = 2,
        write_policy: str = WRITE_BACK,
        write_codec: Optional[ChunkCodec] = None,
    ):
        self.clock = clock
        self.topology = topology
        self.cache = cache
        self.meta = meta
        self.node = node
        self.cal = cal
        self.mdr = cal.default_mdr if mdr is None else mdr
        self.metrics = metrics if metrics is not None else JobMetrics(f"hoardfs:{node.name}")
        self.readahead_window = readahead_window
        self.readahead_inflight = readahead_inflight
        self.readahead_min_streak = readahead_min_streak
        self.write_policy = write_policy
        self.write_codec = write_codec
        self._handles: dict[int, OpenFile] = {}
        self._next_fd = 3                     # 0/1/2 taken, as tradition demands
        # data plane per dataset, keyed by admission generation so a plane
        # never outlives an evict/re-admit cycle of its dataset
        self._planes: dict[str, tuple[int, StripeDataPlane]] = {}
        # write plane per dataset, admission-keyed like the read planes
        self._wplanes: dict[str, tuple[int, WritePlane]] = {}
        self._ra = _RAStats()
        # stall class of the most recent pread/pread_batch (telemetry plane):
        # consumers (FileDataset, TrainingJob) snapshot it right after issuing
        self.last_io_class = "compute"

    # ------------------------------------------------------------- data plane
    def mount(
        self,
        dataset_id: str,
        *,
        fill_plane: Optional[FillTracker] = None,
        prefetcher=None,
        mdr: Optional[float] = None,
        cal: Optional[WorkloadCalibration] = None,
    ) -> str:
        """Wire (or rewire) a dataset's data plane; returns its directory path.

        Explicit mounting is optional — ``open`` auto-mounts with defaults —
        but it is how a caller shares a fill plane / clairvoyant prefetcher
        with other consumers (the workload engine does this), or overrides
        the pagepool MDR and calibration per dataset.
        """
        entry = self._entry(dataset_id)
        plane = self._build_plane(
            dataset_id, fill_plane=fill_plane, prefetcher=prefetcher,
            mdr=mdr, cal=cal,
        )
        self._planes[dataset_id] = (entry.admissions, plane)
        return f"{ROOT}/{dataset_id}"

    def _entry(self, dataset_id: str):
        if dataset_id not in self.cache.entries:
            raise FileNotFoundError(
                2, "dataset striped but not registered with the CacheManager",
                f"{ROOT}/{dataset_id}",
            )
        return self.cache.entries[dataset_id]

    def _build_plane(
        self, dataset_id, *, fill_plane=None, prefetcher=None, mdr=None, cal=None
    ) -> StripeDataPlane:
        entry = self._entry(dataset_id)
        spec = entry.spec
        if cal is None:
            cal = self.cal
            if (
                cal.dataset_items != spec.n_items
                or cal.dataset_bytes != float(spec.total_bytes)
            ):
                cal = replace(
                    cal,
                    dataset_bytes=float(spec.total_bytes),
                    dataset_items=spec.n_items,
                )
        if fill_plane is None and entry.state is CacheState.FILLING:
            plane = entry.fill_plane
            if plane is not None and not plane.cancelled:
                fill_plane = plane
            else:
                fill_plane = FillTracker(
                    self.clock, self.topology, self.cache, dataset_id,
                    metrics=self.metrics,
                )
        n = spec.n_items
        mdr = self.mdr if mdr is None else mdr
        return StripeDataPlane(
            self.clock, self.topology, self.node, cal,
            cache=self.cache, dataset_id=dataset_id,
            pagepool=PagePool(n, buffer_cache_items(mdr, n)),
            metrics=self.metrics, fill_plane=fill_plane, prefetcher=prefetcher,
        )

    def _plane(self, dataset_id: str) -> StripeDataPlane:
        entry = self._entry(dataset_id)
        got = self._planes.get(dataset_id)
        if got is not None and got[0] == entry.admissions:
            return got[1]
        plane = self._build_plane(dataset_id)
        self._planes[dataset_id] = (entry.admissions, plane)
        return plane

    def _write_plane(self, dataset_id: str) -> WritePlane:
        entry = self._entry(dataset_id)
        got = self._wplanes.get(dataset_id)
        if got is not None and got[0] == entry.admissions:
            return got[1]
        plane = WritePlane(
            self.clock, self.topology, self.cache, dataset_id, self.node,
            policy=self.write_policy, codec=self.write_codec, metrics=self.metrics,
        )
        self._wplanes[dataset_id] = (entry.admissions, plane)
        return plane

    # ---------------------------------------------------------- POSIX surface
    def stat(self, path: str) -> FileAttr:
        return self.meta.stat(path)

    def readdir(self, path: str) -> list[str]:
        return self.meta.readdir(path)

    def open(self, path: str, flags: str = "r") -> int:
        """Open a shard file; takes a reader pin for the handle's lifetime.

        ``flags``: ``"r"`` (default) read-only, ``"w"``/``"rw"``/``"r+"``
        writable.  Shard geometry is fixed by the stripe manifest, so a
        writable open never creates or extends a file — it overwrites in
        place, the checkpoint/ingest pattern the write path exists for.
        """
        if flags not in ("r", "w", "rw", "r+"):
            raise ValueError(f"bad flags {flags!r} (want r, w, rw or r+)")
        attr = self.meta.lookup(path)
        if attr.is_dir:
            raise IsADirectoryError(21, "is a directory", path)
        plane = self._plane(attr.dataset_id)
        self.cache.acquire(attr.dataset_id)   # pin: LRU churn can't evict us
        fd = self._next_fd
        self._next_fd += 1
        self._handles[fd] = OpenFile(
            fd=fd, attr=attr, plane=plane,
            readahead=Readahead(
                plane.fill_plane, attr,
                min_streak=self.readahead_min_streak,
                window_chunks=self.readahead_window,
                max_inflight=self.readahead_inflight,
            ),
            writable=flags != "r",
        )
        return fd

    def close(self, fd: int) -> None:
        h = self._handle(fd)
        h.readahead.stop()
        self._ra.fold(h.readahead)
        self.cache.release(h.attr.dataset_id)
        del self._handles[fd]

    def _handle(self, fd: int) -> OpenFile:
        if fd not in self._handles:
            raise OSError(9, "bad file descriptor", str(fd))
        return self._handles[fd]

    def lseek(self, fd: int, offset: int, whence: int = 0) -> int:
        h = self._handle(fd)
        base = {0: 0, 1: h.pos, 2: h.attr.size}.get(whence)
        if base is None:
            raise ValueError(f"bad whence {whence}")
        new = base + offset
        if new < 0:
            raise OSError(22, "invalid seek", h.attr.path)
        h.pos = new
        return new

    def read(self, fd: int, size: int) -> ReadResult:
        """Sequential read at the handle offset (advances it)."""
        h = self._handle(fd)
        res = self.pread(fd, size, h.pos)
        h.pos += res.nbytes
        return res

    def pread(self, fd: int, size: int, offset: int) -> ReadResult:
        """Positional read; the handle offset is not moved (POSIX pread)."""
        h = self._handle(fd)
        attr = h.attr
        nbytes = min(max(0, size), max(0, attr.size - offset))
        items = self.meta.items_for_range(attr, offset, nbytes)
        if len(items) == 0:
            done = self.clock.event()
            done.set()
            return ReadResult(event=done, nbytes=0, data=b"" if self._materialized(attr) else None)
        # hit/blocked accounting BEFORE readahead may react to this read
        if bool(h.plane.filled_mask(items).all()):
            self._ra.hits += 1
        else:
            self._ra.blocked += 1
        h.readahead.observe(offset, nbytes, int(items[0]))
        self.cache.touch(attr.dataset_id)
        ev = h.plane.ondemand_io(items, 0, None)   # positions=None: no pagepool
        self.last_io_class = h.plane.last_io_class
        res = ReadResult(event=ev, nbytes=nbytes)
        if self._materialized(attr):
            # the payload exists only once the fills land; bind it at fire time
            ev.on_fire(
                lambda _v, r=res: setattr(r, "data", self._read_bytes(attr, offset, r.nbytes))
            )
        return res

    def pread_batch(
        self,
        fds: Sequence[int],
        offsets: np.ndarray,
        *,
        epoch: int = 0,
        positions: Optional[np.ndarray] = None,
    ) -> Event:
        """Vectored positional read of one item per ``(fd, offset)`` pair.

        The framework-adapter fast path (:class:`repro.fs.dataset.FileDataset`):
        a DL input pipeline reads one sample per record, so the batch maps
        1:1 onto item ids and the whole step books flows in one
        ``StripeDataPlane.ondemand_io`` call — byte-identical to
        ``HoardBackend.batch_io`` on the same ``(item_ids, epoch,
        positions)``.  Per-handle readahead is not engaged here; batch
        consumers bring their own fill driver (clairvoyant or none).
        """
        fds = np.asarray(fds, dtype=np.int64)
        offsets = np.asarray(offsets, dtype=np.int64)
        if len(fds) != len(offsets):
            raise ValueError("fds and offsets length mismatch")
        if len(fds) == 0:
            done = self.clock.event()
            done.set()
            return done
        item_ids = np.empty(len(fds), dtype=np.int64)
        dataset_id = None
        plane = None
        for fd in np.unique(fds):
            h = self._handle(int(fd))
            if dataset_id is None:
                dataset_id, plane = h.attr.dataset_id, h.plane
            elif h.attr.dataset_id != dataset_id:
                raise ValueError("pread_batch spans datasets; split the batch")
            mask = fds == fd
            item_ids[mask] = h.attr.item_lo + offsets[mask] // h.attr.item_bytes
        self.cache.touch(dataset_id)
        ev = plane.ondemand_io(item_ids, epoch, positions)
        self.last_io_class = plane.last_io_class
        return ev

    # ------------------------------------------------------------ write surface
    def _writable_handle(self, fd: int) -> OpenFile:
        h = self._handle(fd)
        if not h.writable:
            raise OSError(9, "file descriptor opened read-only", h.attr.path)
        return h

    def write(self, fd: int, data) -> WriteResult:
        """Sequential write at the handle offset (advances it)."""
        h = self._writable_handle(fd)
        res = self.pwrite(fd, data, h.pos)
        h.pos += res.nbytes
        return res

    def pwrite(self, fd: int, data, offset: int) -> WriteResult:
        """Positional write; handle offset unmoved (POSIX pwrite).

        ``data`` is ``bytes`` (materialized stores get real read-your-writes
        content) or an ``int`` byte count (accounting-only simulations).
        Writes past EOF raise ``EFBIG`` — shard geometry is fixed by the
        stripe manifest, the façade's documented divergence from growable
        POSIX files.  The result's event fires when the bytes are buffered
        on this mount's node; durability needs :meth:`fsync`.
        """
        h = self._writable_handle(fd)
        attr = h.attr
        nbytes = len(data) if isinstance(data, (bytes, bytearray, memoryview)) else int(data)
        if nbytes < 0:
            raise ValueError(f"negative write size {nbytes}")
        if offset < 0:
            raise OSError(22, "invalid write offset", attr.path)
        if offset + nbytes > attr.size:
            raise OSError(
                27, "write past EOF: shard size is fixed by stripe geometry", attr.path
            )
        if nbytes == 0:
            done = self.clock.event()
            done.set()
            return WriteResult(event=done, nbytes=0)
        man = self.cache.store.manifests[attr.dataset_id]
        wplane = self._write_plane(attr.dataset_id)
        ranges = []
        for chunk, chunk_off, file_lo, seg_len in self.meta.chunk_segments(
            attr, man.chunk_bytes, offset, nbytes
        ):
            if isinstance(data, (bytes, bytearray, memoryview)):
                seg = bytes(data[file_lo - offset : file_lo - offset + seg_len])
            else:
                seg = seg_len
            ranges.append((chunk, chunk_off, seg))
        self.cache.touch(attr.dataset_id)
        return WriteResult(event=wplane.write(ranges), nbytes=nbytes)

    def fsync(self, fd: int) -> Event:
        """Commit this node's buffered writes to the dataset durably.

        Fires with the committed chunk list once every touched chunk is
        replicated (and, under write-through or replication < 2, flushed to
        the remote store).  Commit is atomic across all chunks of the fsync
        — a crash mid-fsync leaves either all of them or none of them
        committed, mirroring ``CheckpointManager``'s atomic-rename contract.
        """
        h = self._writable_handle(fd)
        return self._write_plane(h.attr.dataset_id).fsync()

    # fdatasync carries no extra metadata in this façade; same barrier
    fdatasync = fsync

    def ftruncate(self, fd: int, length: int) -> WriteResult:
        """Truncate-to-length as overwrite: zero-fill ``[length, size)``.

        Shard geometry is fixed, so ``ftruncate`` cannot shrink or grow the
        file's stat size; it implements POSIX's *visible* contract — bytes
        past ``length`` read back as zeros — as a buffered zero write
        (fsync to make it durable).  ``length > size`` raises ``EFBIG``.
        """
        h = self._writable_handle(fd)
        if length < 0:
            raise OSError(22, "negative length", h.attr.path)
        if length > h.attr.size:
            raise OSError(
                27, "cannot extend: shard size is fixed by stripe geometry", h.attr.path
            )
        tail = h.attr.size - length
        if tail == 0:
            done = self.clock.event()
            done.set()
            return WriteResult(event=done, nbytes=0)
        man = self.cache.store.manifests[h.attr.dataset_id]
        data = b"\x00" * tail if man.materialized else tail
        return self.pwrite(fd, data, length)

    # ------------------------------------------------------------- statistics
    def statfs(self) -> "StatFS":
        """Filesystem-wide view: capacity + per-dataset cache state.

        Capacity figures aggregate over the *live membership view* — with an
        elastic rebalancer attached, only member nodes can hold stripes, so
        a node mid-removal stops being counted the instant the epoch bumps
        (its data is still draining, which ``used_bytes`` reflects).  Without
        a rebalancer every node is a member, the pre-elastic behaviour.  A
        specific admission is still bounded by the free bytes of its target
        subset, so ``free_bytes > 0`` does not promise the next ``admit``
        fits — check per-dataset ``nodes`` for locality.  The dataset table
        is :meth:`CacheManager.ls` verbatim — reader pins, live
        ``fill_progress`` and per-dataset ``migrating_chunks``/
        ``membership_epoch`` included, so ``statfs`` during a fill or a
        rebalance shows the cache converging.
        """
        rb = getattr(self.cache, "rebalancer", None)
        if rb is not None:
            nodes = [n for n in self.topology.nodes if n.node_id in rb.members]
        else:
            nodes = self.topology.nodes
        capacity = self.cache.capacity_per_node * len(nodes)
        used = float(sum(self.cache.store.bytes_on_node(n.node_id) for n in nodes))
        # write-path occupancy (satellite fix, ISSUE 6): un-fsync'd buffers
        # sit OUTSIDE used_bytes (the committed copy is what node_usage
        # charges), so free_bytes must subtract them or admission oversubscribes
        # a node whose NVMe holds unflushed writes; dirty bytes are inside
        # used_bytes but reported so operators can see unflushed write-back debt
        write_buffer = float(
            sum(self.cache.store.write_buffer_bytes(n.node_id) for n in nodes)
        )
        dirty = float(sum(self.cache.store.dirty_bytes(n.node_id) for n in nodes))
        return StatFS(
            capacity_bytes=capacity,
            used_bytes=used,
            free_bytes=capacity - used - write_buffer,
            dirty_bytes=dirty,
            write_buffer_bytes=write_buffer,
            read_queue_bytes=float(
                sum(self.cache.store.read_load_bytes(n.node_id) for n in nodes)
            ),
            open_handles=len(self._handles),
            membership_epoch=rb.epoch.value if rb is not None else 0,
            members=sorted(rb.members) if rb is not None else [n.node_id for n in nodes],
            migrating_chunks=sum(
                self.cache.store.migrating_chunks(ds) for ds in self.cache.store.manifests
            ),
            partial_datasets=sum(
                1
                for ds in self.cache.store.manifests
                if self.cache.store.resident_fraction(ds) < 1.0
            ),
            datasets=self.cache.ls(),
            telemetry=(
                self.clock.telemetry.snapshot() if self.clock.telemetry is not None else None
            ),
        )

    def readahead_stats(self) -> dict:
        """Aggregate readahead effectiveness across closed + live handles."""
        agg = _RAStats(
            hits=self._ra.hits, blocked=self._ra.blocked, seeks=self._ra.seeks,
            sequential_reads=self._ra.sequential_reads,
            windows_started=self._ra.windows_started,
        )
        for h in self._handles.values():
            agg.fold(h.readahead)
        reads = agg.hits + agg.blocked
        return {
            "reads": reads,
            "hits": agg.hits,
            "blocked": agg.blocked,
            "hit_rate": agg.hits / reads if reads else 1.0,
            "seeks": agg.seeks,
            "sequential_reads": agg.sequential_reads,
            "windows_started": agg.windows_started,
        }

    # ------------------------------------------------------------- real bytes
    def _materialized(self, attr: FileAttr) -> bool:
        man = self.cache.store.manifests.get(attr.dataset_id)
        return bool(man is not None and man.materialized)

    def _read_bytes(self, attr: FileAttr, offset: int, nbytes: int) -> bytes:
        """Materialized payload for a byte range (post-fill; CRC-verified)."""
        store = self.cache.store
        ib = attr.item_bytes
        out = bytearray()
        start = offset
        end = offset + nbytes
        for item in MetadataService.items_for_range(attr, offset, nbytes):
            blob = store.read_item(attr.dataset_id, int(item), self.node)
            item_start = (int(item) - attr.item_lo) * ib   # file-relative
            lo = max(0, start - item_start)
            hi = min(ib, end - item_start)
            out += blob[lo:hi]
        return bytes(out)
