"""MetadataService: the POSIX namespace over stripe manifests.

The paper's Requirement 4 — "Hoard exposes a POSIX file system interface so
the existing deep learning frameworks can take advantage of the cache
without any modifications" — starts with a namespace.  Every admitted
dataset appears as a directory of fixed-geometry shard files:

    /hoard/                      the mount root (readdir -> dataset dirs)
    /hoard/<dataset>/            one directory per stripe manifest
    /hoard/<dataset>/shard-000042.bin
                                 shard file i covers items
                                 [i*items_per_file, (i+1)*items_per_file)

The namespace is *derived* from ``StripeStore.manifests`` on every call, so
it can never drift from the cache: evicting a dataset removes its directory,
re-admission restores it, and a ``stat`` during an on-demand fill sees the
same manifest the fill plane is writing into.  The only state the service
owns is the file-layout *policy* (items per shard file, per dataset), and
that is exactly what the schema-versioned on-disk format persists — a
remounted HoardFS must lay out byte-identical files or every consumer's
offsets go stale.

Shard size defaults to one stripe chunk per file, which makes the
file -> chunk mapping the identity; any positive ``items_per_file`` works
because the VFS resolves byte ranges through items, not chunks.
"""

from __future__ import annotations

import json
import posixpath
import re
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.stripestore import StripeError, StripeManifest, StripeStore

#: On-disk layout-policy schema.  Bump when the serialized format changes;
#: readers refuse blobs newer than they understand instead of guessing.
FS_SCHEMA_VERSION = 1

ROOT = "/hoard"
_SHARD_RE = re.compile(r"^shard-(\d{6})\.bin$")


def _enoent(path: str) -> FileNotFoundError:
    return FileNotFoundError(2, "no such file or directory", path)


@dataclass(frozen=True)
class FileAttr:
    """``stat`` result: enough geometry for a reader to plan byte IO."""

    path: str
    kind: str                      # "dir" | "file"
    size: int                      # bytes (directories report 0)
    dataset_id: Optional[str] = None
    file_index: int = -1           # shard index within the dataset (-1 for dirs)
    item_lo: int = 0               # first dataset item this shard covers
    n_items: int = 0               # items in this shard (files) / dataset (ds dir)
    item_bytes: int = 0
    # cluster-view generation the placement behind this attr belongs to
    # (StripeManifest.membership_epoch, schema v3); a consumer holding two
    # attrs with different epochs knows the stripes re-balanced in between
    membership_epoch: int = 0

    @property
    def is_dir(self) -> bool:
        return self.kind == "dir"


class MetadataService:
    """``stat`` / ``readdir`` / ``lookup`` over ``/hoard/<dataset>/<shards>``."""

    def __init__(self, store: StripeStore, *, items_per_file: Optional[int] = None):
        self.store = store
        # None -> chunk-sized shards (manifest.items_per_chunk at lookup time)
        self.default_items_per_file = (
            None if items_per_file is None else int(items_per_file)
        )
        self._items_per_file: dict[str, int] = {}    # per-dataset overrides

    # ------------------------------------------------------------ layout policy
    def set_items_per_file(self, dataset_id: str, items_per_file: int) -> None:
        """Pin a dataset's shard geometry (before any consumer opens paths)."""
        if items_per_file <= 0:
            raise ValueError(f"items_per_file must be positive, got {items_per_file}")
        self._items_per_file[dataset_id] = int(items_per_file)

    def items_per_file(self, dataset_id: str) -> int:
        ipf = self._items_per_file.get(dataset_id, self.default_items_per_file)
        if ipf is not None:
            return ipf
        return self._manifest(dataset_id).items_per_chunk

    def _manifest(self, dataset_id: str) -> StripeManifest:
        man = self.store.manifests.get(dataset_id)
        if man is None:
            raise _enoent(f"{ROOT}/{dataset_id}")
        return man

    def n_files(self, dataset_id: str) -> int:
        man = self._manifest(dataset_id)
        ipf = self.items_per_file(dataset_id)
        return (man.n_items + ipf - 1) // ipf

    @staticmethod
    def file_name(index: int) -> str:
        return f"shard-{index:06d}.bin"

    def file_path(self, dataset_id: str, index: int) -> str:
        return f"{ROOT}/{dataset_id}/{self.file_name(index)}"

    # ------------------------------------------------------------- POSIX surface
    @staticmethod
    def _split(path: str) -> list[str]:
        norm = posixpath.normpath("/" + path.strip())
        parts = [p for p in norm.split("/") if p]
        return parts

    def lookup(self, path: str) -> FileAttr:
        """Resolve ``path`` to attributes; raises ``FileNotFoundError``."""
        parts = self._split(path)
        if not parts or parts[0] != ROOT.lstrip("/"):
            raise _enoent(path)
        if len(parts) == 1:
            return FileAttr(path=ROOT, kind="dir", size=0)
        dataset_id = parts[1]
        man = self.store.manifests.get(dataset_id)
        if man is None:
            raise _enoent(path)
        if len(parts) == 2:
            return FileAttr(
                path=f"{ROOT}/{dataset_id}", kind="dir", size=0,
                dataset_id=dataset_id, n_items=man.n_items,
                item_bytes=man.item_bytes,
                membership_epoch=man.membership_epoch,
            )
        if len(parts) > 3:
            raise _enoent(path)
        m = _SHARD_RE.match(parts[2])
        if m is None:
            raise _enoent(path)
        index = int(m.group(1))
        ipf = self.items_per_file(dataset_id)
        item_lo = index * ipf
        if item_lo >= man.n_items:
            raise _enoent(path)
        n_items = min(ipf, man.n_items - item_lo)    # last shard may be short
        return FileAttr(
            path=self.file_path(dataset_id, index), kind="file",
            size=n_items * man.item_bytes, dataset_id=dataset_id,
            file_index=index, item_lo=item_lo, n_items=n_items,
            item_bytes=man.item_bytes,
            membership_epoch=man.membership_epoch,
        )

    # POSIX spelling: stat is lookup that follows no links (we have none)
    stat = lookup

    def readdir(self, path: str) -> list[str]:
        """Directory listing (names only, sorted), like ``os.listdir``."""
        attr = self.lookup(path)
        if not attr.is_dir:
            raise NotADirectoryError(20, "not a directory", path)
        if attr.dataset_id is None:
            return sorted(self.store.manifests)
        return [self.file_name(i) for i in range(self.n_files(attr.dataset_id))]

    # --------------------------------------------------- byte-range resolution
    @staticmethod
    def chunk_segments(
        attr: FileAttr, chunk_bytes: int, offset: int, size: int
    ) -> list[tuple[int, int, int, int]]:
        """Split a shard-file byte range into per-stripe-chunk segments.

        The write path's dual of :meth:`items_for_range`: a ``pwrite`` may
        straddle chunk boundaries (shard geometry is independent of chunk
        geometry), and each segment lands in a different chunk's overlay.
        Returns ``(chunk, chunk_offset, file_lo, seg_len)`` tuples where
        ``file_lo`` is the segment's offset within the *caller's* buffer
        coordinates (file offset space) — so ``data[file_lo - offset :
        file_lo - offset + seg_len]`` is the segment payload.
        """
        if attr.is_dir:
            raise IsADirectoryError(21, "is a directory", attr.path)
        if offset < 0:
            raise ValueError(f"negative offset {offset}")
        end = offset + max(0, size)
        segs: list[tuple[int, int, int, int]] = []
        file_base = attr.item_lo * attr.item_bytes     # dataset byte offset of file[0]
        pos = offset
        while pos < end:
            ds_off = file_base + pos
            chunk = ds_off // chunk_bytes
            chunk_off = ds_off % chunk_bytes
            seg_len = min(end - pos, chunk_bytes - chunk_off)
            segs.append((int(chunk), int(chunk_off), int(pos), int(seg_len)))
            pos += seg_len
        return segs

    @staticmethod
    def items_for_range(attr: FileAttr, offset: int, size: int) -> np.ndarray:
        """Dataset item ids a byte range ``[offset, offset+size)`` touches."""
        if attr.is_dir:
            raise IsADirectoryError(21, "is a directory", attr.path)
        if offset < 0:
            raise ValueError(f"negative offset {offset}")
        end = min(offset + max(0, size), attr.size)
        if offset >= end:
            return np.empty(0, dtype=np.int64)
        first = attr.item_lo + offset // attr.item_bytes
        last = attr.item_lo + (end - 1) // attr.item_bytes
        return np.arange(first, last + 1, dtype=np.int64)

    # ----------------------------------------------------------- on-disk format
    def to_json(self) -> str:
        """Serialize the layout policy (NOT the namespace, which is derived)."""
        return json.dumps(
            {
                "schema_version": FS_SCHEMA_VERSION,
                "default_items_per_file": self.default_items_per_file,
                "items_per_file": dict(self._items_per_file),
            }
        )

    @classmethod
    def from_json(cls, store: StripeStore, blob: str) -> "MetadataService":
        d = json.loads(blob)
        version = d.get("schema_version", 1)
        if version > FS_SCHEMA_VERSION:
            raise StripeError(
                f"HoardFS metadata schema v{version} is newer than this reader "
                f"(v{FS_SCHEMA_VERSION}); refusing to guess"
            )
        svc = cls(store, items_per_file=d.get("default_items_per_file"))
        for ds, ipf in d.get("items_per_file", {}).items():
            svc.set_items_per_file(ds, int(ipf))
        return svc
