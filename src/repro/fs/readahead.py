"""Per-handle sequential readahead: the non-clairvoyant fill driver.

The clairvoyant :class:`~repro.core.prefetch.PrefetchScheduler` needs the
epoch permutation ahead of time — an iterator-world luxury.  A POSIX consumer
gives Hoard nothing but a stream of ``(offset, size)`` reads, which is the
configuration the paper actually runs: the filesystem must *infer* what to
prefetch.  ``Readahead`` does what a kernel readahead window does — detect a
sequential streak per open file handle, then predict "the rest of this file,
in order" and feed that prediction to the *existing* ``PrefetchScheduler``
as if it were a known permutation.  The scheduler machinery (bounded
in-flight transfers, consumer-paced window, resume-skips-filled-chunks) is
reused unchanged; only the source of the order differs:

    clairvoyant:      EpochPlan.order(e)        -> first-touch chunk schedule
    non-clairvoyant:  observed sequential reads -> predicted remaining items

A seek breaks the prediction: the running schedule is stopped (chunks
already demanded were correctly predicted and still land; the *rest* of the
prediction was speculation) and the streak detector starts over from the new
position.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.prefetch import FillTracker, PrefetchScheduler
from .metadata import FileAttr


class Readahead:
    """Sequential-window readahead for one open HoardFS file handle.

    ``observe(offset, size, first_item)`` is called by the VFS on every
    scalar read *before* the read is served, so a confirmed streak starts
    filling ahead of the reader rather than behind it.  With no fill plane
    (dataset fully cached) the detector still runs — the hit/seek statistics
    feed ``fsbench`` — but nothing is scheduled.
    """

    def __init__(
        self,
        tracker: Optional[FillTracker],
        attr: FileAttr,
        *,
        min_streak: int = 2,
        window_chunks: Optional[int] = 8,
        max_inflight: int = 4,
    ):
        self.tracker = tracker
        self.attr = attr
        self.min_streak = max(1, int(min_streak))
        self.window_chunks = window_chunks
        self.max_inflight = max_inflight
        self.scheduler: Optional[PrefetchScheduler] = None
        self._next_offset: Optional[int] = None    # None until the first read
        self._streak = 0
        self._pred_start_chunk = 0                 # chunk the prediction began at
        # ---- statistics (aggregated by HoardFS into readahead_stats())
        self.sequential_reads = 0
        self.seeks = 0
        self.windows_started = 0

    # ---------------------------------------------------------------- observe
    def observe(self, offset: int, size: int, first_item: int) -> None:
        """Feed one read's position to the streak detector (pre-service)."""
        if self._next_offset is not None and offset != self._next_offset:
            self.seeks += 1
            self._streak = 0
            self.stop()                            # prediction invalidated
        else:
            self._streak += 1
            if self._next_offset is not None:
                self.sequential_reads += 1
        self._next_offset = offset + size

        if self.tracker is None or self.tracker.cancelled or self.tracker.complete:
            return
        if self.scheduler is None and self._streak >= self.min_streak:
            self._start(first_item)
        elif self.scheduler is not None:
            # heartbeat: chunks consumed *within the prediction* pace the window
            chunk = first_item // self.tracker._manifest().items_per_chunk
            self.scheduler.note_progress(chunk - self._pred_start_chunk + 1)

    def _start(self, first_item: int) -> None:
        """Predict sequential access to EOF and hand it to the scheduler."""
        man = self.tracker._manifest()
        end_item = self.attr.item_lo + self.attr.n_items
        predicted = np.arange(first_item, end_item, dtype=np.int64)
        if len(predicted) == 0:
            return
        self.scheduler = PrefetchScheduler(
            self.tracker,
            max_inflight=self.max_inflight,
            window_chunks=self.window_chunks,
        )
        self._pred_start_chunk = int(first_item // man.items_per_chunk)
        self.windows_started += 1
        self.scheduler.start(predicted)

    # ------------------------------------------------------------------- stop
    def stop(self) -> None:
        """Abandon the current prediction (seek, or handle close)."""
        if self.scheduler is not None:
            self.scheduler.stop()
            self.scheduler = None
