"""HoardFS: the POSIX-façade filesystem subsystem (paper Requirement 4).

"Hoard exposes a POSIX file system interface so the existing deep learning
frameworks can take advantage of the cache without any modifications" —
this package is that interface for the reproduction:

* :class:`MetadataService` — ``stat``/``readdir``/``lookup`` over the
  ``/hoard/<dataset>/<shard-files>`` namespace, derived live from stripe
  manifests, with a schema-versioned on-disk layout-policy format.
* :class:`HoardFS`        — the VFS: ``open``/``read``/``pread``/
  ``readdir``/``close``/``statfs`` file handles whose reads resolve
  tri-state (stripe hit / fill join / remote fall-through) through the
  shared :class:`~repro.core.loader.StripeDataPlane`, taking CacheManager
  reader pins for the lifetime of every handle.  Writable handles
  (``open(path, "w")``) add ``write``/``pwrite``/``fsync``/``ftruncate``
  over the :class:`~repro.core.writeplane.WritePlane` dirty-chunk
  lifecycle — the bidirectional data plane (ISSUE 6).
* :class:`Readahead`      — per-handle sequential windows feeding the
  existing :class:`~repro.core.prefetch.PrefetchScheduler` from *observed
  file offsets* (the non-clairvoyant mode the paper actually runs).
* :class:`FileDataset` / :func:`posix_loader` — the adapter that lets
  ``TrainingJob`` and ``ClusterScheduler`` workloads be declared as
  path-reading jobs with zero loader changes (``backend="posix"``).

See ``docs/architecture.md`` ("HoardFS") for the VFS -> stripe-store call
path and ``benchmarks/fsbench.py`` for the acceptance measurements.
"""

from .dataset import FileDataset, posix_loader
from .metadata import FS_SCHEMA_VERSION, ROOT, FileAttr, MetadataService
from .readahead import Readahead
from .vfs import HoardFS, OpenFile, ReadResult, StatFS, WriteResult

__all__ = [
    "FS_SCHEMA_VERSION",
    "FileAttr",
    "FileDataset",
    "HoardFS",
    "MetadataService",
    "OpenFile",
    "ROOT",
    "ReadResult",
    "Readahead",
    "StatFS",
    "WriteResult",
    "posix_loader",
]
