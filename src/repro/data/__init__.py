"""Data pipeline: Hoard-cached token corpora for the training loop."""

from .tokens import TokenDatasetSpec, TokenLoader, materialize_token_dataset

__all__ = ["TokenDatasetSpec", "TokenLoader", "materialize_token_dataset"]
