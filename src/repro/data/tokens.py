"""Token data pipeline: Hoard-cached real-bytes datasets -> jnp batches.

Bridges ``repro.core`` (the paper's cache) to JAX training: a synthetic token
corpus is materialised as real chunk files striped across node directories,
and ``TokenLoader`` reads items through the stripe store (CRC-verified,
closest replica) into device-ready (tokens, labels) batches.  The training
loop sees a plain iterator — Requirement 4's transparency — and per-epoch
order is a seeded permutation with resumable state (epoch, step), which the
checkpoint manager persists for deterministic restart.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from ..core import CacheManager, DatasetSpec, Node, StripeStore
from ..train.checkpoint import SamplerState


@dataclass
class TokenDatasetSpec:
    dataset_id: str
    n_sequences: int
    seq_len: int
    vocab: int
    seed: int = 0

    @property
    def item_bytes(self) -> int:
        return self.seq_len * 4              # int32 tokens


def materialize_token_dataset(
    store: StripeStore,
    cache: CacheManager,
    spec: TokenDatasetSpec,
    nodes: list[Node],
    *,
    items_per_chunk: int = 64,
    replication: int = 1,
):
    """Generate + stripe a synthetic corpus as real chunk files."""

    def payload(chunk_idx: int) -> bytes:
        rng = np.random.default_rng((spec.seed, chunk_idx))
        toks = rng.integers(
            0, spec.vocab, (items_per_chunk, spec.seq_len), dtype=np.int32
        )
        return toks.tobytes()

    dspec = DatasetSpec(
        spec.dataset_id, f"synthetic://{spec.dataset_id}", spec.n_sequences, spec.item_bytes
    )
    if spec.dataset_id not in cache.entries:
        cache.register(dspec)
    cache.admit(
        spec.dataset_id, nodes, materialize=True, payload=payload,
        items_per_chunk=items_per_chunk,
    )
    cache.mark_filled(spec.dataset_id)
    return dspec


class TokenLoader:
    """Iterates (tokens, labels) batches from striped chunks; resumable."""

    def __init__(
        self,
        store: StripeStore,
        spec: TokenDatasetSpec,
        reader: Node,
        *,
        batch: int,
        state: Optional[SamplerState] = None,
    ):
        self.store = store
        self.spec = spec
        self.reader = reader
        self.batch = batch
        self.state = state or SamplerState(seed=spec.seed)

    def _order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.spec.seed, epoch))
        return rng.permutation(self.spec.n_sequences)

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        while True:
            order = self._order(self.state.epoch)
            steps = len(order) // self.batch
            while self.state.step_in_epoch < steps:
                s = self.state.step_in_epoch
                ids = order[s * self.batch : (s + 1) * self.batch]
                toks = np.stack([self._read_item(i) for i in ids])
                self.state.step_in_epoch += 1
                labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
                yield toks, labels
            self.state.epoch += 1
            self.state.step_in_epoch = 0

    def _read_item(self, item: int) -> np.ndarray:
        raw = self.store.read_item(self.spec.dataset_id, int(item), self.reader)
        return np.frombuffer(raw, np.int32).reshape(self.spec.seq_len).copy()
