"""Serving: batched KV-cache decode engine."""

from .engine import ServeConfig, ServingEngine
from .flash_decoding import make_flash_decode

__all__ = ["ServeConfig", "ServingEngine", "make_flash_decode"]
