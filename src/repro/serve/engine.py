"""Batched serving engine: warm-cache decode over Hoard-fed request batches.

Serving is the paper's "different invocations of jobs that share the same
data" story in its purest form: prompt datasets live in the Hoard cache and
every engine restart hits warm stripes instead of the remote store.

The engine runs: (1) cache init, (2) chunked prefill that fills the KV cache
through repeated ``decode_step`` calls or a single prefill pass for scoring,
(3) a jit'd decode loop producing one token per step for the whole batch
(greedy or temperature sampling).  Caches are donated across steps.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import params as PM


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0         # 0 = greedy
    seed: int = 0


class ServingEngine:
    def __init__(self, model, params, *, cache_len: int, batch: int, enc_len: int = 0):
        self.model = model
        self.params = params
        self.batch = batch
        self.cache_len = cache_len
        cfg = model.cfg
        if cfg.family == "encdec":
            lay = model.cache_layout(batch, cache_len, enc_len or 64)
        else:
            lay = model.cache_layout(batch, cache_len)
        self.cache = PM.materialize(lay, jax.random.PRNGKey(0), cfg.dtype)  # zeros
        self._decode = jax.jit(model.decode_step, donate_argnames=())

    def prefill_tokens(self, prompts: np.ndarray) -> jax.Array:
        """Feed prompts token-by-token through decode_step (cache warmup).

        Production would use a chunked prefill kernel; the engine exercises
        the same cache-update path the long-decode cells lower.
        """
        B, S = prompts.shape
        assert B == self.batch
        logits = None
        for t in range(S):
            batch = {
                "tokens": jnp.asarray(prompts[:, t : t + 1], jnp.int32),
                "cache": self.cache,
                "index": jnp.asarray(t, jnp.int32),
            }
            logits, self.cache = self._decode(self.params, batch)
        return logits

    def generate(self, prompts: np.ndarray, cfg: Optional[ServeConfig] = None) -> np.ndarray:
        cfg = cfg or ServeConfig()
        key = jax.random.PRNGKey(cfg.seed)
        logits = self.prefill_tokens(prompts)
        pos = prompts.shape[1]
        out = []
        tok = self._sample(logits, cfg, key)
        for i in range(cfg.max_new_tokens):
            out.append(np.asarray(tok)[:, 0])
            batch = {
                "tokens": tok,
                "cache": self.cache,
                "index": jnp.asarray(pos + i, jnp.int32),
            }
            logits, self.cache = self._decode(self.params, batch)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, cfg, sub)
        return np.stack(out, axis=1)

    @staticmethod
    def _sample(logits, cfg: ServeConfig, key) -> jax.Array:
        last = logits[:, -1]
        if cfg.temperature <= 0:
            return jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, last / cfg.temperature)[:, None].astype(jnp.int32)
