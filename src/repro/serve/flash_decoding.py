"""Sequence-parallel decode attention: flash-decoding across chips.

The baseline decode path shards the KV cache's sequence dimension on the
``model`` axis and lets GSPMD partition the softmax; this module does it
*explicitly* with ``shard_map``: every chip computes a partial online-softmax
(m, l, acc) over its local KV shard, and partials merge with one small
all-reduce-style combine — the cross-chip mirror of the Pallas
``decode_attention`` kernel's block algebra (same math, chip-sized blocks).

Why it matters at scale: GQA head counts in the pool (5, 10, 20, 25) do not
divide a 16-way TP axis, so head-sharding cannot cover decode; sequence
sharding works for every arch and keeps the per-chip cache slice O(S/16).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

_NEG = -1e30


def _partial_softmax(q, k_shard, v_shard, pos0, valid_len):
    """Per-chip partial attention.  q: (B,Hq,1,hd); shards: (B,Hkv,Sl,hd).

    Returns (m, l, acc): running max, denominator, unnormalised output.
    """
    B, Hq, _, hd = q.shape
    _, Hkv, Sl, _ = k_shard.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, 1, hd).astype(jnp.float32) * (hd ** -0.5)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_shard.astype(jnp.float32))
    pos = pos0 + jnp.arange(Sl)
    mask = pos < valid_len
    s = jnp.where(mask[None, None, None, None], s, _NEG)
    m = s.max(-1)                                            # (B,Hkv,G,1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_shard.astype(jnp.float32))
    return m, l, acc


def make_flash_decode(mesh, axis: str = "model"):
    """Returns fn(q, k_cache, v_cache, valid_len) with seq-sharded caches.

    q replicated over ``axis``; caches sharded P(..., axis, ...) on seq.
    The combine uses the flash merge: with global maximum m*,
    out = sum_i exp(m_i - m*) acc_i / sum_i exp(m_i - m*) l_i.
    """
    n_shards = mesh.shape[axis]

    def fn(q, k_cache, v_cache, valid_len):
        B, Hq, _, hd = q.shape

        def shard_fn(q, k_shard, v_shard, valid):
            idx = jax.lax.axis_index(axis)
            Sl = k_shard.shape[2]
            m, l, acc = _partial_softmax(q, k_shard, v_shard, idx * Sl, valid)
            m_star = jax.lax.pmax(m, axis)
            scale = jnp.exp(m - m_star)
            l_tot = jax.lax.psum(l * scale, axis)
            acc_tot = jax.lax.psum(acc * scale[..., None], axis)
            out = acc_tot / jnp.where(l_tot == 0, 1.0, l_tot)[..., None]
            return out.reshape(B, Hq, 1, hd).astype(v_shard.dtype)

        return shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(P(), P(None, None, axis, None), P(None, None, axis, None), P()),
            out_specs=P(),
        )(q, k_cache, v_cache, valid_len)

    return fn
